"""Autotuning subsystem: table round-trip and robustness, tile resolution
(tuned-vs-heuristic), memo-key discrimination, candidate search, shape
capture/harvest, and the repro-tune CLI."""

import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without the `test` extra
    from _hypothesis_fallback import given, settings, st

from repro import tune
from repro.core.engine import OPOPE_16x16_FP16
from repro.core.tiling import rank_plans
from repro.kernels import ops
from repro.kernels.opope_gemm import VMEM_BUDGET_BYTES, validate_block_shape
from repro.kernels.ref import reference_matmul
from repro.tune import (
    GemmShape,
    TuneEntry,
    TuneKey,
    TuningTable,
    candidate_blocks,
    tune_shape,
    tune_workload,
)

import repro.quant  # noqa: F401  (registers the q8 backends)


def _entry(
    backend="pallas_interpret", family="dense", m=64, k=128, n=128, g=0,
    dtype="float32", device=None, block=(32, 128, 128), us=10.0,
):
    return TuneEntry(
        key=TuneKey(
            backend=backend, shape_family=family, m=m, k=k, n=n, g=g,
            dtype=dtype,
            device_kind=device if device is not None else tune.device_kind(),
        ),
        block=tuple(block), us=us, gflops=1.0,
    )


@pytest.fixture
def table_env(tmp_path, monkeypatch):
    """Point the active table at a tmp path; restore heuristics afterwards."""
    path = str(tmp_path / "table.json")
    monkeypatch.setenv(tune.ENV_VAR, path)
    ops.clear_tile_cache()
    yield path
    ops.clear_tile_cache()


@pytest.fixture(autouse=True)
def _drop_table_state_after():
    yield
    ops.clear_tile_cache()


# ---------------------------------------------------------------------------
# Table round-trip + robustness (corrupt / missing / stale schema)
# ---------------------------------------------------------------------------


class TestTableRoundTrip:
    def test_save_load_lookup(self, table_env):
        t = TuningTable([
            _entry(block=(48, 128, 128)),
            _entry(family="grouped", g=4, block=(16, 128, 128)),
        ])
        t.save(table_env)
        t2 = TuningTable.load(table_env)
        assert len(t2) == 2
        assert t2.lookup(
            backend="pallas_interpret", shape_family="dense",
            m=64, k=128, n=128, g=0, itemsize=4,
        ) == (48, 128, 128)
        assert t2.lookup(
            backend="pallas_interpret", shape_family="grouped",
            m=64, k=128, n=128, g=4, itemsize=4,
        ) == (16, 128, 128)

    def test_lookup_is_by_itemsize_not_dtype_name(self, table_env):
        t = TuningTable([_entry(dtype="float32", block=(48, 128, 128))])
        # int32 shares float32's width: same tile serves both (the
        # heuristics key on elem bytes for the same reason).
        assert t.lookup(
            backend="pallas_interpret", shape_family="dense",
            m=64, k=128, n=128, g=0, itemsize=4,
        ) == (48, 128, 128)
        assert t.lookup(
            backend="pallas_interpret", shape_family="dense",
            m=64, k=128, n=128, g=0, itemsize=2,
        ) is None

    def test_other_device_entries_not_served(self, table_env):
        t = TuningTable([_entry(device="tpu-v5e", block=(48, 128, 128))])
        assert t.lookup(
            backend="pallas_interpret", shape_family="dense",
            m=64, k=128, n=128, g=0, itemsize=4,
        ) is None  # current device is cpu

    def test_missing_file_falls_back_silently(self, table_env):
        # table_env points at a path that was never written
        with warnings.catch_warnings():
            warnings.simplefilter("error", RuntimeWarning)
            assert ops.tile_source(
                "pallas_interpret", 64, 128, 128
            ) == "heuristic"
            out = ops.matmul(
                jnp.ones((8, 16), jnp.float32), jnp.ones((16, 8), jnp.float32),
                backend="pallas_interpret",
            )
        np.testing.assert_allclose(np.asarray(out), 16.0)

    def test_corrupt_json_warns_once_and_falls_back(self, table_env):
        with open(table_env, "w") as f:
            f.write("{not json")
        with pytest.warns(RuntimeWarning, match="unusable tuning table"):
            src = ops.tile_source("pallas_interpret", 64, 128, 128)
        assert src == "heuristic"
        # the failed load is cached: no warning storm on the hot path
        with warnings.catch_warnings():
            warnings.simplefilter("error", RuntimeWarning)
            assert ops.tile_source(
                "pallas_interpret", 64, 128, 128
            ) == "heuristic"

    def test_stale_schema_falls_back(self, table_env):
        doc = TuningTable([_entry()]).to_json()
        doc["schema"] = 999
        with open(table_env, "w") as f:
            json.dump(doc, f)
        with pytest.warns(RuntimeWarning, match="schema"):
            assert ops.tile_source(
                "pallas_interpret", 64, 128, 128
            ) == "heuristic"

    def test_malformed_entry_falls_back(self, table_env):
        with open(table_env, "w") as f:
            json.dump({"schema": 1, "entries": [{"backend": "x"}]}, f)
        with pytest.warns(RuntimeWarning, match="unusable tuning table"):
            assert ops.tile_source(
                "pallas_interpret", 64, 128, 128
            ) == "heuristic"


# ---------------------------------------------------------------------------
# Tile resolution: tuned-first, validated, family-discriminated
# ---------------------------------------------------------------------------


class TestTileResolution:
    def test_tuned_tile_wins_and_matmul_stays_correct(self, table_env):
        heur = ops.heuristic_tile("pallas_interpret", 64, 128, 128)
        tuned = (8, 128, 128)
        assert tuned != heur
        TuningTable([_entry(block=tuned)]).save(table_env)
        ops.clear_tile_cache()
        assert ops.tile_source("pallas_interpret", 64, 128, 128) == "tuned"
        assert ops.tile_for("pallas_interpret", 64, 128, 128) == tuned
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
        got = ops.matmul(a, b, backend="pallas_interpret")
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(reference_matmul(a, b)),
            rtol=1e-5, atol=1e-4,
        )

    def test_clear_tile_cache_drops_loaded_table(self, table_env):
        TuningTable([_entry(block=(8, 128, 128))]).save(table_env)
        ops.clear_tile_cache()
        assert ops.tile_source("pallas_interpret", 64, 128, 128) == "tuned"
        os.unlink(table_env)
        # loaded state persists until explicitly dropped...
        assert ops.tile_source("pallas_interpret", 64, 128, 128) == "tuned"
        ops.clear_tile_cache()
        # ...and the next resolution re-reads the (now missing) file
        assert ops.tile_source("pallas_interpret", 64, 128, 128) == "heuristic"

    def test_dense_and_grouped_entries_do_not_collide(self, table_env):
        TuningTable([
            _entry(family="dense", block=(64, 128, 128)),
            _entry(family="grouped", g=2, block=(32, 128, 128)),
        ]).save(table_env)
        ops.clear_tile_cache()
        assert ops.tile_for("pallas_interpret", 64, 128, 128) == (64, 128, 128)
        assert ops.tile_for(
            "pallas_interpret", 64, 128, 128, groups=2
        ) == (32, 128, 128)
        # a grouped entry for g=2 is not served for g=4
        assert ops.tile_source(
            "pallas_interpret", 64, 128, 128, groups=4
        ) == "heuristic"

    def test_memo_key_discriminates_family_and_groups(self):
        ops.clear_tile_cache()
        ops._tile_for(64, 128, 128, 4, "dense", 0, "pallas_interpret")
        ops._tile_for(64, 128, 128, 4, "grouped", 2, "pallas_interpret")
        ops._tile_for(64, 128, 128, 4, "grouped", 4, "pallas_interpret")
        assert ops.tile_cache_info().currsize == 3

    def test_legacy_positional_call_still_works(self):
        # the PR-1 memo shape (m, k, n, itemsize): used by older tests/tools
        bm, bn, bk = ops._tile_for(256, 512, 256, 2)
        assert bn % 128 == 0 and bk % 128 == 0

    def test_invalid_tuned_tiles_rejected_with_warning(self, table_env):
        bad_bn = _entry(block=(64, 100, 128))  # bn not 128-aligned
        TuningTable([bad_bn]).save(table_env)
        ops.clear_tile_cache()
        with pytest.warns(RuntimeWarning, match="violates kernel constraints"):
            src = ops.tile_source("pallas_interpret", 64, 128, 128)
        assert src == "heuristic"

    def test_vmem_busting_tile_rejected(self, table_env):
        huge = (1024, 1024, 4096)  # acc + double-buffered panels >> budget
        assert not validate_block_shape(*huge, elem_bytes=4)
        TuningTable([_entry(block=huge)]).save(table_env)
        ops.clear_tile_cache()
        with pytest.warns(RuntimeWarning, match="violates kernel constraints"):
            assert ops.tile_source(
                "pallas_interpret", 64, 128, 128
            ) == "heuristic"

    def test_q8_sublane_alignment_enforced(self, table_env):
        # bm=8 is legal for the fp kernel but not the int8 one (32-sublane)
        TuningTable([
            _entry(backend="pallas_q8_interpret", dtype="int8",
                   block=(8, 128, 128)),
        ]).save(table_env)
        ops.clear_tile_cache()
        with pytest.warns(RuntimeWarning, match="violates kernel constraints"):
            src = ops.tile_source("pallas_q8_interpret", 64, 128, 128)
        assert src == "heuristic"
        # 32-aligned is accepted
        TuningTable([
            _entry(backend="pallas_q8_interpret", dtype="int8",
                   block=(32, 128, 128)),
        ]).save(table_env)
        ops.clear_tile_cache()
        assert ops.tile_source("pallas_q8_interpret", 64, 128, 128) == "tuned"

    def test_untiled_backends_always_heuristic(self, table_env):
        TuningTable([_entry(backend="xla")]).save(table_env)
        ops.clear_tile_cache()
        # xla has no tile knob: even with a table entry under its name,
        # tile_source reports heuristic (the entry is inert).
        assert ops.tile_source("xla", 64, 128, 128) == "heuristic"
        assert "xla" not in ops.tunable_backends()

    def test_tunable_backends_lists_the_pallas_family(self):
        tunable = set(ops.tunable_backends())
        assert {"pallas", "pallas_interpret",
                "pallas_q8", "pallas_q8_interpret"} <= tunable
        assert "xla" not in tunable and "xla_q8" not in tunable


# ---------------------------------------------------------------------------
# Candidate search + analytic pruning
# ---------------------------------------------------------------------------


class TestSearch:
    def test_candidates_all_valid_and_deduped(self):
        cands = candidate_blocks(200, 300, 500, itemsize=4)
        assert cands and len(set(cands)) == len(cands)
        for bm, bn, bk in cands:
            assert validate_block_shape(bm, bn, bk, elem_bytes=4)

    def test_q8_candidates_respect_sublane(self):
        for bm, bn, bk in candidate_blocks(100, 256, 256, itemsize=1,
                                           m_align=32):
            assert bm % 32 == 0

    def test_rank_plans_orders_by_modeled_cycles(self):
        cands = [(64, 128, 128), (8, 128, 128), (128, 256, 256)]
        ranked = rank_plans(
            OPOPE_16x16_FP16, 256, 256, 256, cands, elem_bytes=4, top_k=3
        )
        cycles = [c for _, c in ranked]
        assert cycles == sorted(cycles)
        assert len(ranked) == 3

    def test_tune_shape_heuristic_always_measured(self):
        shape = GemmShape("dense", 48, 96, 72, 0, "float32")
        entry, results = tune_shape(
            "pallas_interpret", shape, top_k=2, iters=1
        )
        assert any(r.is_heuristic for r in results)
        heur = next(r for r in results if r.is_heuristic)
        assert entry.us <= heur.us  # winner can only confirm or improve
        assert entry.block in [r.block for r in results]
        assert validate_block_shape(*entry.block, elem_bytes=4)
        # epilogue-capable backend + default probe: a verdict was recorded
        assert entry.fuse_epilogue in (True, False)

    def test_tune_shape_probe_opt_out_leaves_verdict_unset(self):
        shape = GemmShape("dense", 48, 96, 72, 0, "float32")
        entry, _ = tune_shape(
            "pallas_interpret", shape, top_k=1, iters=1, probe_epilogue=False
        )
        assert entry.fuse_epilogue is None

    def test_probe_epilogue_fusion_times_both_lanes(self):
        from repro.tune import probe_epilogue_fusion

        probe = probe_epilogue_fusion(
            "pallas_interpret", GemmShape("dense", 48, 128, 128),
            (48, 128, 128), iters=1,
        )
        assert probe.fused_us > 0 and probe.posthoc_us > 0
        assert probe.decided_us == min(probe.fused_us, probe.posthoc_us)
        assert probe.fuse == (probe.fused_us <= probe.posthoc_us)
        with pytest.raises(ValueError, match="not tunable"):
            probe_epilogue_fusion(
                "xla", GemmShape("dense", 8, 8, 8), (8, 128, 128)
            )

    def test_tune_shape_rejects_untunable_backend(self):
        with pytest.raises(ValueError, match="no tile knob"):
            tune_shape("xla", GemmShape("dense", 8, 8, 8))

    def test_tune_workload_roundtrip_through_ops(self, table_env):
        shapes = [
            GemmShape("dense", 48, 128, 128, 0, "float32"),
            GemmShape("grouped", 32, 128, 128, 2, "float32"),
        ]
        table = tune_workload(
            shapes, backends=["pallas_interpret"], top_k=1, iters=1
        )
        assert len(table) == 2
        table.save(table_env)
        ops.clear_tile_cache()
        assert ops.tile_source("pallas_interpret", 48, 128, 128) == "tuned"
        assert ops.tile_source(
            "pallas_interpret", 32, 128, 128, groups=2
        ) == "tuned"
        # and the tuned grouped path still computes the right thing
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.standard_normal((2, 32, 128)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((2, 128, 128)), jnp.float32)
        got = ops.grouped_matmul(a, b, backend="pallas_interpret")
        want = jnp.einsum("gmk,gkn->gmn", a, b)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )


# ---------------------------------------------------------------------------
# Shape capture + workload harvest
# ---------------------------------------------------------------------------


class TestCapture:
    def test_capture_records_dense_and_grouped(self):
        with tune.capture_gemm_shapes() as shapes:
            ops.matmul(
                jnp.zeros((4, 8, 16), jnp.float32),
                jnp.zeros((16, 8), jnp.float32), backend="xla",
            )
            ops.grouped_matmul(
                jnp.zeros((3, 8, 16), jnp.float32),
                jnp.zeros((3, 16, 8), jnp.float32), backend="xla",
            )
        assert GemmShape("dense", 32, 16, 8, 0, "float32") in shapes
        assert GemmShape("grouped", 8, 16, 8, 3, "float32") in shapes

    def test_capture_dedupes_and_works_under_tracing(self):
        a = jnp.zeros((8, 16), jnp.float32)
        b = jnp.zeros((16, 8), jnp.float32)
        with tune.capture_gemm_shapes() as shapes:
            jax.eval_shape(
                lambda a, b: ops.matmul(a, b, backend="xla")
                + ops.matmul(a, b, backend="xla"),
                a, b,
            )
        assert shapes == [GemmShape("dense", 8, 16, 8, 0, "float32")]

    def test_capture_nests_without_corrupting_the_stack(self):
        a = jnp.zeros((8, 16), jnp.float32)
        b = jnp.zeros((16, 8), jnp.float32)
        with tune.capture_gemm_shapes() as outer:
            with tune.capture_gemm_shapes() as inner:
                pass  # equal (empty) record lists: must detach by identity
            ops.matmul(a, b, backend="xla")
        assert inner == []
        assert outer == [GemmShape("dense", 8, 16, 8, 0, "float32")]
        assert ops._SHAPE_CAPTURE == []

    def test_capture_off_records_nothing(self):
        before = len(ops._SHAPE_CAPTURE)
        ops.matmul(jnp.zeros((8, 16)), jnp.zeros((16, 8)), backend="xla")
        assert len(ops._SHAPE_CAPTURE) == before == 0

    def test_harvest_dense_model(self):
        shapes = tune.harvest_model_shapes("xlstm-125m", batch=1, seq=32)
        assert shapes
        assert all(isinstance(s, GemmShape) for s in shapes)
        assert all(s.family == "dense" for s in shapes)
        assert len(set(shapes)) == len(shapes)

    def test_harvest_moe_model_sees_grouped_experts(self):
        shapes = tune.harvest_model_shapes("deepseek-moe-16b", batch=1, seq=64)
        grouped = [s for s in shapes if s.family == "grouped"]
        assert grouped and all(s.g > 0 for s in grouped)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


class TestCLI:
    def test_cli_tunes_writes_and_activates(self, table_env, capsys):
        from repro.launch import tune as tune_cli

        tune_cli.main([
            "--shapes", "48x128x128", "--grouped", "2x32x128x128",
            "--backends", "pallas_interpret", "--iters", "1", "--top-k", "1",
        ])
        out = capsys.readouterr().out
        assert "wrote 2 entries" in out
        assert "active while REPRO_TUNE_TABLE=" in out
        doc = json.load(open(table_env))
        assert doc["schema"] == 1 and len(doc["entries"]) == 2
        # the CLI refreshes the calling process's table state itself —
        # no manual clear_tile_cache() needed to see the new entries
        assert ops.tile_source("pallas_interpret", 48, 128, 128) == "tuned"

    def test_cli_merges_by_default(self, table_env, capsys):
        TuningTable([_entry(m=8, k=128, n=128, block=(8, 128, 128))]).save(
            table_env
        )
        from repro.launch import tune as tune_cli

        tune_cli.main([
            "--shapes", "48x128x128", "--backends", "pallas_interpret",
            "--iters", "1", "--top-k", "1",
        ])
        assert "merging into 1 existing entries" in capsys.readouterr().out
        assert len(TuningTable.load(table_env)) == 2

    def test_cli_rejects_untunable_backend(self, table_env):
        from repro.launch import tune as tune_cli

        with pytest.raises(SystemExit, match="not tunable"):
            tune_cli.main(["--shapes", "8x8x8", "--backends", "xla"])

    def test_cli_rejects_unavailable_backend(self, table_env):
        # compiled pallas is tunable in principle but cannot lower on CPU:
        # the CLI must say so instead of dying inside the kernel
        from repro.launch import tune as tune_cli

        with pytest.raises(SystemExit, match="not available"):
            tune_cli.main(["--shapes", "8x8x8", "--backends", "pallas"])


# ---------------------------------------------------------------------------
# Hypothesis properties
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    backend=st.sampled_from(["pallas", "pallas_q8", "pallas_interpret"]),
    family=st.sampled_from(["dense", "grouped"]),
    m=st.integers(1, 1 << 20),
    k=st.integers(1, 1 << 20),
    n=st.integers(1, 1 << 20),
    g=st.integers(0, 4096),
    dtype=st.sampled_from(["float32", "bfloat16", "int8"]),
    device=st.sampled_from(["cpu", "tpu-v5e", "tpu-v6-lite"]),
)
def test_key_json_roundtrip(backend, family, m, k, n, g, dtype, device):
    key = TuneKey(backend, family, m, k, n, g, dtype, device)
    assert TuneKey.from_json(json.loads(json.dumps(key.to_json()))) == key


@settings(max_examples=50, deadline=None)
@given(
    m=st.integers(1, 4096),
    k=st.integers(1, 8192),
    n=st.integers(1, 4096),
    itemsize=st.sampled_from([1, 2, 4]),
)
def test_candidate_blocks_property(m, k, n, itemsize):
    m_align = 32 if itemsize == 1 else 8
    for bm, bn, bk in candidate_blocks(
        m, k, n, itemsize=itemsize, m_align=m_align
    ):
        assert bm % m_align == 0 and bn % 128 == 0 and bk % 128 == 0
        assert bm * bn * 4 + 2 * (bm * bk + bk * bn) * itemsize \
            <= VMEM_BUDGET_BYTES
